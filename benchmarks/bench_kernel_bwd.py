"""Paper Figs. 8/9 analogue: deterministic backward-pass throughput per schedule.

Per (mask × schedule × head_dim):
  us_per_call — wall time of the *jitted jnp reference backward* on this CPU
     (an honest measured number; the Pallas kernel itself targets TPU and is
     correctness-validated in interpret mode, not timed);
  derived — modeled TPU utilization of the DASH-scheduled kernel from the DAG
     simulator at calibrated r/c (see bench_schedule_sim.rc_ratio), i.e. the
     quantity Figs. 8/9 plot as throughput, normalized to the fa3 baseline.

Also writes ``benchmarks/BENCH_kernel_bwd.json`` comparing the two kernel
realizations of every schedule (grid-step counts + modeled makespans):

  serialized       grid = (bh, n_tasks) on one sequential core — makespan is
                   Σ over worker chains; a W-core part sits at 1/W utilization.
  worker_parallel  grid = (bh, n_workers, max_chain_len) with the worker axis
                   parallel — modeled makespan is the *max* chain (plus the
                   schedule's reduction stalls), i.e. the quantity DASH
                   actually minimizes. Sentinel padding steps are counted;
                   they issue no DMAs.
"""
import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.bench_schedule_sim import rc_ratio
from repro.core import schedules as S
from repro.core import simulator as sim
from repro.kernels import ref

ART = os.path.join(os.path.dirname(__file__), "BENCH_kernel_bwd.json")


def _measure_ref_bwd(seq, head_dim, causal, reps=3):
    bh = max(1, 16384 // seq) * 2
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q, k, v, do = (jax.random.normal(kk, (bh, seq, head_dim), jnp.float32)
                   for kk in ks)
    out, lse = ref.mha_fwd(q, k, v, causal)

    f = jax.jit(lambda *a: ref.mha_bwd(*a, causal=causal))
    r = f(q, k, v, out, lse, do)
    jax.block_until_ready(r)
    t0 = time.perf_counter()
    for _ in range(reps):
        r = f(q, k, v, out, lse, do)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps * 1e6


def _sched(nm, n, m, causal):
    return S.cached_schedule(nm, n, n_heads=m, causal=causal)


def grid_realizations(nm, n, causal, c, r):
    """Grid-step counts + modeled makespans for both kernel realizations.

    Uses the n_heads=1 schedule — exactly what the kernel grids run (the bh
    grid dimension covers batch·heads).
    """
    sch = _sched(nm, n, 1, causal)
    wc = sch.worker_chains()
    n_tasks = sum(int(v) for v in wc["valid"].sum(1))
    w, t = wc["kv_ids"].shape
    res = sim.simulate(sch, c, r)
    max_chain = max(len(chain) for chain in sch.chains) * (c + r)
    serialized_makespan = n_tasks * (c + r)
    return {
        "schedule": nm,
        "causal": causal,
        "n": n,
        "serialized": {
            "grid_steps": n_tasks,
            "modeled_makespan": serialized_makespan,       # Σ chains
            # one core busy, W-1 idle on a W-worker part
            "modeled_utilization": round(1.0 / w, 4),
        },
        "worker_parallel": {
            "grid_steps_per_worker": t,
            "n_workers": w,
            "sentinel_steps": w * t - n_tasks,
            "modeled_makespan": res.makespan,              # ≈ max chain
            "max_chain": max_chain,
            "makespan_over_max_chain": round(res.makespan / max_chain, 4),
            "modeled_utilization": round(res.utilization, 4),
        },
        "modeled_speedup": round(serialized_makespan / res.makespan, 3),
        "bitwise_identical": bool(wc["single_visit"]),
    }


def main():
    artifact = {"rc_ratios": {}, "realizations": []}
    for head_dim in (64, 128):
        c, r = 1.0, rc_ratio(head_dim)
        artifact["rc_ratios"][str(head_dim)] = round(r, 4)
        for seq in (512, 2048, 8192):
            n = max(2, min(seq // 128, 64))
            m = 8
            for causal in (False, True):
                us = _measure_ref_bwd(min(seq, 2048), head_dim, causal)
                base = sim.simulate(S.fa3(n, m, causal), c, r).makespan
                names = (["fa3", "descending", "symmetric_shift"] if causal
                         else ["fa3", "descending", "shift"])
                for nm in names:
                    res = sim.simulate(_sched(nm, n, m, causal), c, r)
                    print(f"kernel_bwd_{'causal' if causal else 'full'}"
                          f"_hd{head_dim}_s{seq}_{nm},{us:.1f},"
                          f"modeled_util={res.utilization:.3f}"
                          f";speedup={base / res.makespan:.3f}")
                    if head_dim == 64:  # grid shape is head_dim-independent
                        artifact["realizations"].append(
                            grid_realizations(nm, n, causal, c, r))
    json.dump(artifact, open(ART, "w"), indent=1)
    print(f"kernel_bwd_artifact,0.0,wrote={os.path.basename(ART)}")


if __name__ == "__main__":
    main()
