"""Paper Figs. 3/4/6/7 + §3 closed forms: DAG-simulated makespans and the
modeled backward-throughput speedups of Figs. 8/9.

The paper measures H800 wall-clock; we cannot. The DAG model (validated to
reproduce the paper's closed forms *exactly* — see tests/test_core_schedules.py)
is evaluated over the paper's benchmark grid: total tokens 16384, seq 512..16k,
head dims {64,128}, BF16. c and r are set from tile-level arithmetic:
  c ∝ 4·Bq·Bk·d MACs on the MXU; r ∝ dQ tile HBM read-modify-write bytes,
so r/c = (peak_flops/HBM_bw) · (bytes per dQ elem)/(flops per score elem) — on
v5e (197e12/819e9) r/c ≈ 0.30 for d=64 and 0.15 for d=128 at 128×128 tiles.
"""
import time

from repro.core import schedules as S
from repro.core import simulator as sim


def rc_ratio(head_dim: int, block: int = 128) -> float:
    flops_per_task = 4 * 2 * block * block * head_dim          # 4 GEMMs fwd+bwd-ish
    dq_rmw_bytes = 2 * block * head_dim * 4                    # fp32 read+write
    peak_flops, hbm = 197e12, 819e9
    return (dq_rmw_bytes / hbm) / (flops_per_task / peak_flops)


def rows():
    out = []
    total_tokens = 16384
    for head_dim in (64, 128):
        r_over_c = rc_ratio(head_dim)
        for seq in (512, 1024, 2048, 4096, 8192, 16384):
            n = max(2, seq // 128)          # KV tiles = workers (paper WLOG)
            m = 2 * max(1, total_tokens // seq)  # heads in flight (batch*heads)
            c, r = 1.0, r_over_c
            for causal in (False, True):
                base = sim.simulate(S.fa3(n, m, causal), c, r).makespan
                names = (["descending", "symmetric_shift"] if causal
                         else ["descending", "shift"])
                for nm in names:
                    t0 = time.perf_counter()
                    sch = (S.make_schedule(nm, n, m, causal) if nm != "descending"
                           else S.descending(n, m, causal))
                    ms = sim.simulate(sch, c, r).makespan
                    el = (time.perf_counter() - t0) * 1e6
                    out.append((f"sim_{'causal' if causal else 'full'}"
                                f"_hd{head_dim}_s{seq}_{nm}", el,
                                f"speedup_vs_fa3={base / ms:.3f}"))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
