"""Block-sparse mask scheduling benchmark (ISSUE 5): modeled makespans and
kernel grid-step counts for sliding-window and packed-document masks, serialized
vs worker-parallel realizations and shift vs fa3-order placement.

CSV lines: ``masks_<mask>_n<i>_<placement>`` with the measured *jnp dense-mask
reference backward* wall time (honest CPU number; the Pallas kernels target TPU
and are correctness-validated in interpret mode) and the modeled utilization /
speedup of the DASH-scheduled kernel.

Writes ``benchmarks/BENCH_masks.json``:
  * per mask × n: fwd grid-step savings vs the dense grid (EMPTY tiles
    removed), serialized makespan (Σ chains), worker-parallel modeled makespan
    (simulator), ragged lower bound, and whether shift placement achieves it
    (``optimal``);
  * shift vs fa3-order placement speedup — the golden property CI re-checks
    (benchmarks/check_mask_placement.py).
"""
import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.bench_schedule_sim import rc_ratio
from repro.core import simulator as sim
from repro.kernels import ref
from repro.kernels.flash_fwd import mask_grid
from repro.masks import Document, PrefixLM, SlidingWindow, \
    compile_block_schedule, streaming_mask

ART = os.path.join(os.path.dirname(__file__), "BENCH_masks.json")
BLK = 128


def _mask_cases(n):
    s = n * BLK
    third = (s // 3) // BLK * BLK or BLK
    return [
        ("sliding_window", SlidingWindow(third)),
        ("document", Document.from_lengths((s // 4, s // 2,
                                            s - s // 4 - s // 2))),
        ("prefix_lm", PrefixLM(s // 4)),
        ("streaming", streaming_mask(third, BLK)),
    ]


def _measure_ref_bwd(seq, mask, reps=3):
    bh = max(1, 8192 // seq) * 2
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q, k, v, do = (jax.random.normal(kk, (bh, seq, 64), jnp.float32)
                   for kk in ks)
    dense = mask.materialize(seq)
    out, lse = ref.mha_fwd(q, k, v, mask=dense)

    f = jax.jit(lambda *a: ref.mha_bwd(*a, mask=dense))
    r = f(q, k, v, out, lse, do)
    jax.block_until_ready(r)
    t0 = time.perf_counter()
    for _ in range(reps):
        r = f(q, k, v, out, lse, do)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps * 1e6


def realization_stats(mask, n, c, r):
    """Grid/makespan comparison for one mask at n×n tiles."""
    entry = {"n": n, "mask": mask.key()}
    kv_ids, _, _, _, partial = mask_grid(mask, n, n, BLK, BLK)
    entry["fwd"] = {
        "grid_steps": int(kv_ids.shape[0]),
        "dense_grid_steps": n * n,
        "empty_tiles_removed": n * n - int(kv_ids.shape[0]),
        "partial_tiles": int(partial.sum()),
    }
    placements = {}
    for placement in ("shift", "fa3"):
        sch = compile_block_schedule(mask, n, n, BLK, BLK,
                                     placement=placement)
        res = sim.simulate(sch, c, r)
        wc = sch.worker_chains()
        n_tasks = len(sch.cells)
        w, t = wc["kv_ids"].shape
        lb = sim.ragged_lower_bound(sch, c, r)
        placements[placement] = {
            "n_workers": w,
            "serialized": {"grid_steps": n_tasks,
                           "modeled_makespan": n_tasks * (c + r)},
            "worker_parallel": {
                "grid_steps_per_worker": t,
                "sentinel_steps": w * t - n_tasks,
                "modeled_makespan": res.makespan,
                "modeled_utilization": round(res.utilization, 4),
            },
            "lower_bound": lb,
            "optimal": bool(abs(res.makespan - lb) < 1e-9),
        }
    entry["placements"] = placements
    entry["shift_vs_fa3_speedup"] = round(
        placements["fa3"]["worker_parallel"]["modeled_makespan"]
        / placements["shift"]["worker_parallel"]["modeled_makespan"], 4)
    return entry


def main():
    c, r = 1.0, rc_ratio(64)
    artifact = {"rc_ratio": round(r, 4), "block": BLK, "cases": []}
    for n in (8, 16, 32):
        for name, mask in _mask_cases(n):
            entry = realization_stats(mask, n, c, r)
            entry["name"] = name
            artifact["cases"].append(entry)
            if n == 16:
                us = _measure_ref_bwd(min(n * BLK, 2048), mask)
                shift = entry["placements"]["shift"]
                print(f"masks_{name}_n{n}_shift,{us:.1f},"
                      f"modeled_util="
                      f"{shift['worker_parallel']['modeled_utilization']}"
                      f";vs_fa3_order={entry['shift_vs_fa3_speedup']}"
                      f";empty_removed={entry['fwd']['empty_tiles_removed']}")
    json.dump(artifact, open(ART, "w"), indent=1)
    print(f"masks_artifact,0.0,wrote={os.path.basename(ART)}")


if __name__ == "__main__":
    main()
