"""Paper Fig. 10 analogue: end-to-end transformer-block speedup.

Fig. 10 evaluates LLaMA3-8b / Qwen2.5-7b / Mixtral-8x7b (causal, batch 1, seq
8k–32k) and SAM-huge / SD3.5-m / SD3.5-L / LLaDA-1b (full mask, batch 16, seq
~4k), reporting 2–10% (causal) and ~4% (full) block-level speedups from swapping
the deterministic attention backward for DASH.

Method: the attention-backward share of a block's fwd+bwd time is computed
analytically from FLOPs (share = 2·F_attn_core / (3·(F_attn_core + F_linear)),
with F_attn_core = 4·S²·d the score/PV flops and F_linear the qkvo+FFN matmuls),
then Amdahl's law with two kernel-speedup figures:
  * modeled  — the DAG-model schedule gap (an upper bound; assumes zero-cost
    dependency edges, the paper's idealization),
  * paper    — the paper's measured 1.28× H800 ceiling (their §4 hardware
    effects: L2 latency, register pressure).
us_per_call = measured CPU wall time of one scaled block fwd+bwd (sanity anchor).
"""
import time

import jax
import jax.numpy as jnp

from benchmarks.bench_schedule_sim import rc_ratio
from repro.core import schedules as S
from repro.core import simulator as sim
from repro.kernels import ref

# name: (d_model, n_heads, d_ff, gated, causal, seq)
MODELS = {
    "llama3-8b_8k": (4096, 32, 14336, True, True, 8192),
    "llama3-8b_16k": (4096, 32, 14336, True, True, 16384),
    "llama3-8b_32k": (4096, 32, 14336, True, True, 32768),
    "qwen2.5-7b_16k": (3584, 28, 18944, True, True, 16384),
    "mixtral-8x7b_16k": (4096, 32, 14336, True, True, 16384),
    "sam-huge_4k": (1280, 16, 5120, False, False, 4096),
    "sd3.5-medium_4k": (1536, 24, 6144, False, False, 4096),
    "sd3.5-large_4k": (2432, 38, 9728, False, False, 4096),
    "llada-1b_4k": (2048, 32, 5632, True, False, 4096),
}
PAPER_KERNEL_SPEEDUP = 1.28


def _measure_block(d_model, n_heads, d_ff, gated, causal, seq, scale=16):
    s = max(256, seq // scale)
    hd = d_model // n_heads
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (1, s, d_model), jnp.float32)
    wqkv = jax.random.normal(ks[1], (d_model, 3 * d_model), jnp.float32) * 0.02
    wo = jax.random.normal(ks[2], (d_model, d_model), jnp.float32) * 0.02
    w1 = jax.random.normal(ks[3], (d_model, d_ff), jnp.float32) * 0.02
    w2 = jax.random.normal(ks[4], (d_ff, d_model), jnp.float32) * 0.02

    def block(x):
        qkv = x @ wqkv
        q, k, v = jnp.split(qkv, 3, -1)
        rs = lambda t: t.reshape(1, s, n_heads, hd).transpose(0, 2, 1, 3) \
            .reshape(-1, s, hd)
        o, _ = ref.mha_fwd(rs(q), rs(k), rs(v), causal)
        o = o.reshape(1, n_heads, s, hd).transpose(0, 2, 1, 3).reshape(1, s, -1)
        h = x + o @ wo
        return h + jax.nn.silu(h @ w1) @ w2

    g = jax.jit(jax.grad(lambda z: jnp.sum(block(z).astype(jnp.float32))))
    r = g(x)
    jax.block_until_ready(r)
    t0 = time.perf_counter()
    jax.block_until_ready(g(x))
    return (time.perf_counter() - t0) * 1e6


def attn_bwd_share(d_model, d_ff, gated, causal, seq):
    f_attn = 4 * seq * seq * d_model * (0.5 if causal else 1.0)
    f_linear = 8 * seq * d_model ** 2 + (6 if gated else 4) * seq * d_model * d_ff
    return 2 * f_attn / (3 * (f_attn + f_linear))


def main():
    for name, (d, h, f, gated, causal, seq) in MODELS.items():
        us = _measure_block(d, h, f, gated, causal, seq)
        share = attn_bwd_share(d, f, gated, causal, seq)
        n = max(2, min(seq // 128, 64))
        r_over_c = rc_ratio(d // h)
        base = sim.simulate(S.fa3(n, 8, causal), 1.0, r_over_c).makespan
        best = sim.simulate(
            S.make_schedule("symmetric_shift" if causal else "shift", n, 8,
                            causal), 1.0, r_over_c).makespan
        k_model = base / best
        e2e_model = 1.0 / (1.0 - share + share / k_model)
        e2e_paper = 1.0 / (1.0 - share + share / min(k_model,
                                                     PAPER_KERNEL_SPEEDUP))
        print(f"e2e_block_{name},{us:.0f},"
              f"attn_bwd_share={share:.3f};e2e_speedup_modeled={e2e_model:.3f};"
              f"e2e_speedup_paper_anchored={e2e_paper:.3f}")


if __name__ == "__main__":
    main()
