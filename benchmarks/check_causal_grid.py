"""CI gate: the causal flash-forward grid must contain ZERO fully-masked tiles.

The schedule-driven forward (`repro.kernels.flash_fwd.causal_grid`) enumerates
only tiles that intersect the causal mask; this check re-derives the valid set
for a sweep of tilings and fails the build if the grid ever re-admits a masked
tile (or drops a valid one, or stops iterating q descending). Run by CI:

    PYTHONPATH=src python benchmarks/check_causal_grid.py
"""
import sys

from repro.kernels.flash_fwd import causal_grid

SWEEP = [
    # (n_q, n_k, block_q, block_k)
    (2, 2, 128, 128), (3, 3, 128, 128), (8, 8, 128, 128), (64, 64, 128, 128),
    (4, 8, 128, 64), (8, 4, 64, 128), (16, 16, 256, 256),
]


def check(n_q, n_k, bq, bk):
    kv_ids, q_ids, first, last = causal_grid(n_q, n_k, bq, bk)
    tasks = list(zip(kv_ids.tolist(), q_ids.tolist()))
    valid = {(ki, qi) for qi in range(n_q) for ki in range(n_k)
             if ki * bk < (qi + 1) * bq}
    masked = [t for t in tasks if t not in valid]
    if masked:
        return f"grid contains {len(masked)} fully-masked tiles: {masked[:4]}"
    if set(tasks) != valid or len(tasks) != len(valid):
        return "grid does not cover the valid tile set exactly once"
    q_order = [q for i, q in enumerate(q_ids.tolist()) if first[i]]
    if q_order != sorted(q_order, reverse=True):
        return "q tiles not iterated descending"
    dense = n_q * n_k
    return None, len(tasks), dense


def main() -> int:
    failures = []
    for cfg in SWEEP:
        res = check(*cfg)
        if isinstance(res, str):
            failures.append((cfg, res))
            print(f"FAIL {cfg}: {res}")
        else:
            _, n_tasks, dense = res
            print(f"ok   n_q={cfg[0]:>3} n_k={cfg[1]:>3} bq={cfg[2]} bk={cfg[3]}"
                  f": {n_tasks} tasks (dense grid: {dense}, "
                  f"{dense - n_tasks} masked tiles removed)")
    if failures:
        print(f"{len(failures)} causal-grid check(s) failed", file=sys.stderr)
        return 1
    print("causal forward grid: zero fully-masked tiles across the sweep")
    return 0


if __name__ == "__main__":
    sys.exit(main())
