"""Benchmark harness — one module per paper table/figure. CSV: name,us_per_call,derived.

  bench_schedule_sim   Figs. 3/4/6/7 + §3 closed forms (DAG model)
  bench_kernel_bwd     Figs. 8/9 backward throughput per schedule; writes
                       BENCH_kernel_bwd.json (serialized vs worker-parallel
                       grid realizations: steps, modeled makespan/utilization)
  bench_e2e_block      Fig. 10 end-to-end transformer-block speedup
  bench_determinism    Table 1 gradient-deviation
  bench_roofline       §Roofline terms from the dry-run artifacts (ours)
  bench_ring           cross-chip ring attention, contig vs zigzag (ours)
  bench_serve          continuous-batching vs static serving tokens/s (ours)
  bench_masks          block-sparse mask schedules: sliding-window/document/
                       prefix/streaming grids, shift vs fa3-order placement;
                       writes BENCH_masks.json (ours)
"""
import importlib
import sys
import traceback

MODULES = [
    "benchmarks.bench_schedule_sim",
    "benchmarks.bench_kernel_bwd",
    "benchmarks.bench_e2e_block",
    "benchmarks.bench_determinism",
    "benchmarks.bench_roofline",
    "benchmarks.bench_ring",
    "benchmarks.bench_serve",
    "benchmarks.bench_masks",
]


def main() -> None:
    print("name,us_per_call,derived")
    failed = []
    for mod_name in MODULES:
        try:
            importlib.import_module(mod_name).main()
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            failed.append(mod_name)
    if failed:
        print(f"FAILED benchmarks: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
