"""Benchmark harness — one module per paper table/figure. CSV: name,us_per_call,derived.

  bench_schedule_sim   Figs. 3/4/6/7 + §3 closed forms (DAG model)
  bench_kernel_bwd     Figs. 8/9 backward throughput per schedule; writes
                       BENCH_kernel_bwd.json (serialized vs worker-parallel
                       grid realizations: steps, modeled makespan/utilization)
  bench_e2e_block      Fig. 10 end-to-end transformer-block speedup
  bench_determinism    Table 1 gradient-deviation
  bench_roofline       §Roofline terms from the dry-run artifacts (ours)
  bench_ring           cross-chip ring attention, contig vs zigzag (ours)
  bench_serve          continuous-batching vs static serving tokens/s (ours)
  bench_masks          block-sparse mask schedules: sliding-window/document/
                       prefix/streaming grids, shift vs fa3-order placement;
                       writes BENCH_masks.json (ours)

After the suites run, ``summarize()`` folds every BENCH_*.json artifact into
one consolidated ``BENCH_summary.json`` — one row per suite with its headline
metric plus modeled/achieved utilization where the suite produces them — the
single file CI uploads and dashboards read.  ``--summary-only`` rebuilds the
summary from the committed artifacts without re-running anything.
"""
import argparse
import importlib
import json
import os
import sys
import traceback

MODULES = [
    "benchmarks.bench_schedule_sim",
    "benchmarks.bench_kernel_bwd",
    "benchmarks.bench_e2e_block",
    "benchmarks.bench_determinism",
    "benchmarks.bench_roofline",
    "benchmarks.bench_ring",
    "benchmarks.bench_serve",
    "benchmarks.bench_masks",
]

ART_DIR = os.path.dirname(os.path.abspath(__file__))
SUMMARY_PATH = os.path.join(ART_DIR, "BENCH_summary.json")


def _load(name):
    path = os.path.join(ART_DIR, name)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def _row(suite, headline, value, unit, modeled_util=None, achieved_util=None,
         **extra):
    row = {"suite": suite, "headline": headline,
           "value": None if value is None else round(float(value), 4),
           "unit": unit,
           "modeled_utilization": None if modeled_util is None
           else round(float(modeled_util), 4),
           "achieved_utilization": None if achieved_util is None
           else round(float(achieved_util), 4)}
    row.update(extra)
    return row


def summarize():
    """One consolidated row per suite from the BENCH_*.json artifacts.

    modeled utilization comes from the DAG model (simulator); achieved
    utilization is measured/modeled where a suite times real work against its
    model — suites that emit only one of the two leave the other null.
    """
    rows = []

    kb = _load("BENCH_kernel_bwd.json")
    if kb:
        reals = kb.get("realizations", [])
        best = max(reals, key=lambda r: r.get("modeled_speedup", 0.0),
                   default=None)
        if best:
            rows.append(_row(
                "kernel_bwd", "best worker-parallel modeled speedup",
                best["modeled_speedup"], "x",
                modeled_util=best["worker_parallel"]["modeled_utilization"],
                modeled_makespan=best["worker_parallel"].get(
                    "modeled_makespan"),
                schedule=best["schedule"], causal=best["causal"],
                bitwise_identical=all(r.get("bitwise_identical")
                                      for r in reals)))

    bm = _load("BENCH_masks.json")
    if bm:
        cases = bm.get("cases", [])
        utils, optimal = [], 0
        for case in cases:
            sh = case.get("placements", {}).get("shift", {})
            wp = sh.get("worker_parallel", {})
            if "modeled_utilization" in wp:
                utils.append(wp["modeled_utilization"])
            optimal += bool(sh.get("optimal"))
        rows.append(_row(
            "masks", "shift placements at the modeled lower bound",
            optimal, "cases",
            modeled_util=(sum(utils) / len(utils)) if utils else None,
            n_cases=len(cases)))

    br = _load("BENCH_ring.json")
    if br:
        cases = br.get("cases", {})
        contig = cases.get("ring_bwd_causal_contig")
        zigzag = cases.get("ring_bwd_causal_zigzag")
        rows.append(_row(
            "ring", "causal bwd zigzag vs contig",
            (contig / zigzag) if contig and zigzag else None, "x",
            device_count=br.get("device_count")))

    bs = _load("BENCH_serve.json")
    if bs:
        cases = bs.get("cases", {})
        rows.append(_row(
            "serve", "continuous vs static-b1 decode throughput",
            cases.get("continuous_vs_static_b1"), "x",
            decode_tps=cases.get("continuous_s4_decode_tps"),
            n_slots=bs.get("n_slots"),
            # speculative decoding (verified exact acceptance) axis
            spec_speedup_k4=cases.get("spec_k4_vs_nonspec"),
            spec_accept_rate=cases.get("spec_k4_accept_rate"),
            # sharded-engine axis (tokens bitwise == single-device per run)
            tp_decode_tps={f"tp{n}": cases.get(f"continuous_tp{n}_decode_tps")
                           for n in bs.get("tp_degrees", [])}))

    summary = {"suites": rows, "source": "benchmarks/run.py summarize()"}
    with open(SUMMARY_PATH, "w") as f:
        json.dump(summary, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"[summary] {len(rows)} suites -> {SUMMARY_PATH}")
    return summary


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--summary-only", action="store_true",
                    help="skip the benchmark suites; rebuild "
                         "BENCH_summary.json from the committed artifacts")
    args = ap.parse_args(argv)
    if args.summary_only:
        summarize()
        return

    print("name,us_per_call,derived")
    failed = []
    for mod_name in MODULES:
        try:
            importlib.import_module(mod_name).main()
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            failed.append(mod_name)
    summarize()
    if failed:
        print(f"FAILED benchmarks: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
