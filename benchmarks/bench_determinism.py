"""Paper Table 1: max gradient deviation over 10 identical backward passes,
non-deterministic (emulated unordered atomic accumulation) vs deterministic
(schedule-ordered accumulation). M_r = max |q_r - q_ref|.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import determinism as det
from repro.core.schedules import make_schedule
from repro.kernels import ref


def grad_partials(causal: bool, seed=0, bh=4, seq=512, d=64, block=128):
    """Per-KV-tile dQ partials of a real attention backward (the operands whose
    accumulation order is at stake), fp32 math, cast bf16 like FA3's HBM adds.

    dS is computed once with the correct (masked) softmax; the per-tile partial
    is dQ_t = dS[:, :, tile] @ K[tile] — exactly the quantity each KV-tile worker
    contributes in Alg. 1 line 28."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q, k, v, do = (jax.random.normal(kk, (bh, seq, d), jnp.float32) for kk in ks)
    out, lse = ref.mha_fwd(q, k, v, causal)
    sm = 1.0 / (d ** 0.5)
    s = ref._mask(ref._logits(q, k, sm), causal)
    p = jnp.exp(s - lse[..., None])
    dp = jnp.einsum("bqd,bkd->bqk", do, v)
    delta = jnp.sum(do * out.astype(jnp.float32), axis=-1)
    ds = p * (dp - delta[..., None]) * sm
    n = seq // block
    parts = []
    for t in range(n):
        ksl = slice(t * block, (t + 1) * block)
        dq_t = jnp.einsum("bqk,bkd->bqd", ds[:, :, ksl], k[:, ksl])
        parts.append(dq_t)
    return jnp.stack(parts)       # (n_kv_tiles, BH, S, D) fp32


def main():
    rng = np.random.RandomState(0)
    for causal in (False, True):
        t0 = time.perf_counter()
        parts32 = grad_partials(causal)
        n = parts32.shape[0]
        order = [kv for kv, _ in make_schedule(
            "symmetric_shift" if causal else "shift", n, 2 if causal else 1,
            causal).reduction_order[(0, n - 1)]]
        mask = "causal" if causal else "full"
        # fp32 accumulators = the paper's Table-1 setting (atomicAdd on fp32 dQ);
        # bf16 shows the magnified deviation of low-precision accumulation.
        for dt, parts in (("fp32", parts32),
                          ("bf16", parts32.astype(jnp.bfloat16))):
            def nondet(i):
                perm = rng.permutation(n) if i else np.arange(n)
                return det.permuted_sum(parts, perm)

            dev_nd = det.max_deviation(nondet, None, n_runs=10)
            dev_d = det.max_deviation(
                lambda i: det.schedule_ordered_dq(parts, order), None, 10)
            us = (time.perf_counter() - t0) * 1e6
            print(f"determinism_{mask}_{dt},{us:.0f},"
                  f"nondet_max_dev={dev_nd:.2e};det_max_dev={dev_d:.2e}")
            assert dev_d == 0.0


if __name__ == "__main__":
    main()
