"""Serving throughput: continuous batching (paged KV) vs. the static engine.

Emits CSV rows plus benchmarks/BENCH_serve.json with prefill and decode
tokens/s on the reduced config.  The headline number is
``continuous_vs_static_b1`` — aggregate continuous-batching decode throughput
over a 4-slot engine relative to static single-stream decode; the acceptance
bar (ISSUE 2) is >= 2x.  The continuous engine pays for its determinism
bookkeeping (host page tables, per-request sampling keys) with in-flight
batching: 4 requests advance per device dispatch instead of 1.

The ``tp`` axis (``continuous_tp{n}_decode_tps``) times the same engine
sharded over an n-way model mesh for every n ≤ len(jax.devices()) in
{1, 2, 4}, asserting the emitted tokens stay bitwise equal to the
single-device run (the topology-invariance contract) — on a plain 1-CPU CI
host only tp1 runs; the sharded-serve CI job forces 4 host devices to cover
the full axis.

``--spec-k`` adds the speculative-decoding axis
(``spec_k{n}_decode_tps`` / ``spec_k{n}_accept_rate`` /
``spec_k{n}_vs_nonspec``): self-draft greedy engines at each k, tokens
asserted bitwise against the non-speculative run (the exact-acceptance
contract, README §Serving).  Self-draft acceptance is 1.0 by construction,
so the measured ratio is pure dispatch fusion — one ``lax.scan`` of k+1
(slots, 1) steps per round instead of k+1 host round-trips; the acceptance
bar (ISSUE 9) is >= 2x at k=4.

``--preempt-rate`` adds the robustness axis
(``continuous_preempt{pct}_decode_tps``): deterministic slot-revocation
faults every ``1/rate`` engine steps force preempt + recompute-restore
cycles; tokens are asserted bitwise against the fault-free run (the
determinism-under-faults contract, README §Robustness), and the recorded
degradation ratio is the price of a preemption at that rate.
"""
import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.base import InputShape
from repro.launch.specs import make_batch
from repro.models import transformer as T
from repro.serve.engine import ContinuousEngine, Engine

ART = os.path.join(os.path.dirname(__file__), "BENCH_serve.json")

PROMPT, GEN, N_REQ, SLOTS = 32, 48, 8, 4


def _row(name, us, derived):
    print(f"{name},{us:.0f},{derived}", flush=True)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preempt-rate", type=float, nargs="*", default=None,
                    metavar="RATE",
                    help="also bench under revoke_slot faults at these rates "
                         "(faults per engine step, e.g. 0.05 0.15); no value "
                         "= default axis [0.05, 0.15]")
    ap.add_argument("--spec-k", type=int, nargs="*", default=None,
                    metavar="K",
                    help="also bench self-draft speculative decoding at "
                         "these draft lengths (bitwise-asserted vs the "
                         "non-speculative run); no value = default axis "
                         "[2, 4]")
    args = ap.parse_args(argv)
    preempt_rates = args.preempt_rate
    if preempt_rates is not None and not preempt_rates:
        preempt_rates = [0.05, 0.15]
    spec_ks = args.spec_k
    if spec_ks is not None and not spec_ks:
        spec_ks = [2, 4]

    cfg = registry.get("stablelm-1.6b").reduced()
    params = T.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    results = {"config": "stablelm-1.6b/reduced", "prompt": PROMPT, "gen": GEN,
               "n_requests": N_REQ, "n_slots": SLOTS, "cases": {}}

    # ---- static engine: single-stream and full-batch decode ----------------
    for b in (1, 4):
        batch = make_batch(cfg, InputShape("s", "prefill", PROMPT, b),
                           jax.random.PRNGKey(1))["batch"]
        eng = Engine(cfg, params, max_seq=PROMPT + GEN)
        jax.block_until_ready(eng._prefill(params, batch)[0])   # compile
        t0 = time.perf_counter()
        jax.block_until_ready(eng._prefill(params, batch)[0])
        prefill_s = time.perf_counter() - t0
        eng.generate(batch, 4)                          # warm both dispatch paths
        t0 = time.perf_counter()
        jax.block_until_ready(eng.generate(batch, GEN))
        dt = time.perf_counter() - t0
        tps = b * GEN / dt
        results["cases"][f"static_b{b}_decode_tps"] = tps
        results["cases"][f"static_b{b}_prefill_tps"] = b * PROMPT / prefill_s
        _row(f"serve_static_b{b}_decode", dt / (b * GEN) * 1e6, f"{tps:.0f}tok/s")

    # ---- continuous engine: N_REQ requests over SLOTS slots ----------------
    prompts = [rng.randint(1, cfg.vocab, size=PROMPT).tolist()
               for _ in range(N_REQ)]

    def build(mesh=None, faults=None, **kw):
        eng = ContinuousEngine(cfg, params, n_slots=SLOTS,
                               max_seq=PROMPT + GEN + 16, page_size=16,
                               prefill_chunk=PROMPT, mesh=mesh, faults=faults,
                               **kw)
        for i in range(N_REQ):
            eng.submit(prompts[i], req_id=i, max_new_tokens=GEN)
        return eng

    build().run()                                       # compile both shapes
    eng = build()
    t0 = time.perf_counter()
    out = eng.run()
    dt = time.perf_counter() - t0
    total = sum(len(v) for v in out.values())
    tps = total / dt
    results["cases"]["continuous_s4_decode_tps"] = tps
    results["cases"]["continuous_decode_steps"] = eng.decode_steps
    _row("serve_continuous_s4", dt / total * 1e6, f"{tps:.0f}tok/s")

    ratio = tps / results["cases"]["static_b1_decode_tps"]
    results["cases"]["continuous_vs_static_b1"] = ratio
    _row("serve_continuous_vs_static_b1", 0, f"{ratio:.2f}x")

    # ---- tp axis: sharded engine, tokens asserted bitwise vs. out ----------
    base_tokens = {r: v.tolist() for r, v in out.items()}
    devs = np.array(jax.devices())
    tps_axis = [n for n in (1, 2, 4) if n <= len(devs)]
    results["tp_degrees"] = tps_axis
    for n in tps_axis:
        mesh = jax.sharding.Mesh(devs[:n], ("model",))
        build(mesh).run()                               # compile
        eng = build(mesh)
        t0 = time.perf_counter()
        out_tp = eng.run()
        dt = time.perf_counter() - t0
        for r, v in out_tp.items():
            assert v.tolist() == base_tokens[r], (
                f"tp{n} tokens diverged from single-device on request {r}")
        tp_tps = sum(len(v) for v in out_tp.values()) / dt
        results["cases"][f"continuous_tp{n}_decode_tps"] = tp_tps
        _row(f"serve_continuous_tp{n}", dt * 1e6 / max(1, GEN * N_REQ),
             f"{tp_tps:.0f}tok/s,bitwise")

    # ---- spec axis: self-draft speculation, tokens bitwise vs. out ---------
    if spec_ks:
        results["spec_ks"] = spec_ks
        for k in spec_ks:
            build(spec_k=k).run()                       # compile the scan
            eng = build(spec_k=k)
            t0 = time.perf_counter()
            out_s = eng.run()
            dt = time.perf_counter() - t0
            for r, v in out_s.items():
                assert v.tolist() == base_tokens[r], (
                    f"spec_k={k} tokens diverged from non-speculative on "
                    f"request {r}")
            s_tps = sum(len(v) for v in out_s.values()) / dt
            rate = eng.spec.acceptance_rate()
            assert rate == 1.0, f"self-draft acceptance {rate} != 1.0"
            results["cases"][f"spec_k{k}_decode_tps"] = s_tps
            results["cases"][f"spec_k{k}_accept_rate"] = rate
            results["cases"][f"spec_k{k}_vs_nonspec"] = s_tps / tps
            results["cases"][f"spec_k{k}_decode_steps"] = eng.decode_steps
            _row(f"serve_spec_k{k}", dt * 1e6 / max(1, GEN * N_REQ),
                 f"{s_tps:.0f}tok/s,accept={rate:.2f},"
                 f"{s_tps / tps:.2f}x,bitwise")

    # ---- preemption axis: throughput vs deterministic revoke_slot rate -----
    if preempt_rates:
        from repro.faults import Fault, FaultPlan, Injector
        results["preempt_rates"] = preempt_rates
        for rate in preempt_rates:
            period = max(1, int(round(1.0 / rate)))
            # literal (non-seeded) plan: one victim eviction every `period`
            # engine steps across a horizon comfortably past the drain point
            plan = FaultPlan(name=f"bench-preempt-{rate}", faults=tuple(
                Fault(s, "revoke_slot", arg=1)
                for s in range(period, 20 * (GEN + 4), period)))
            build(faults=Injector(plan)).run()          # compile/warm
            eng = build(faults=Injector(plan))
            t0 = time.perf_counter()
            out_p = eng.run()
            dt = time.perf_counter() - t0
            for r, v in out_p.items():
                assert v.tolist() == base_tokens[r], (
                    f"preempt-rate {rate} tokens diverged on request {r}")
            p_tps = sum(len(v) for v in out_p.values()) / dt
            pct = int(round(rate * 100))
            results["cases"][f"continuous_preempt{pct}_decode_tps"] = p_tps
            results["cases"][f"continuous_preempt{pct}_vs_clean"] = p_tps / tps
            results["cases"][f"continuous_preempt{pct}_preemptions"] = (
                eng.preemptions)
            _row(f"serve_continuous_preempt{pct}",
                 dt * 1e6 / max(1, GEN * N_REQ),
                 f"{p_tps:.0f}tok/s,{eng.preemptions}preempts,"
                 f"{p_tps / tps:.2f}x,bitwise")

    with open(ART, "w") as f:
        json.dump(results, f, indent=1)


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    main()
