"""CI gate: generalized shift placement must beat fa3-order placement on
ragged columns, and hit the ragged lower bound where its rotation assignment
is collision-free.

Golden properties, re-derived per run (no stored goldens to go stale):
  1. for every mask in the sweep, simulate(shift) <= simulate(fa3-order);
  2. for the stacked-column masks (document, prefix-LM) the inequality is
     STRICT — fa3-order serializes the column heads (the Fig. 3 cascade),
     shift staggers them;
  3. shift's simulated makespan equals ``ragged_lower_bound`` (== the DAG
     critical path, Lemma-1 monotone) on the window/document/streaming
     families — the optimality certificate;
  4. every compiled schedule passes ``Schedule.validate()``.

Run by CI:  PYTHONPATH=src python benchmarks/check_mask_placement.py
"""
import sys

from repro.core import dag as dag_mod
from repro.core import simulator as sim
from repro.masks import (Document, PrefixLM, SlidingWindow,
                         compile_block_schedule, streaming_mask)

C, R = 1.0, 0.5
BLK = 128


def sweep():
    for n in (4, 8, 16, 32):
        s = n * BLK
        yield ("sliding_window", n, SlidingWindow(max(BLK, s // 3)), True)
        yield ("document", n,
               Document.from_lengths((s // 4, s // 2, s - s // 4 - s // 2)),
               True)
        yield ("prefix_lm", n, PrefixLM(s // 4), False)
        yield ("streaming", n, streaming_mask(max(BLK, s // 4), BLK), True)


STRICT = {"document", "prefix_lm"}


def check(name, n, mask, expect_optimal):
    shift = compile_block_schedule(mask, n, n, BLK, BLK)
    fa3 = compile_block_schedule(mask, n, n, BLK, BLK, placement="fa3")
    shift.validate()
    fa3.validate()
    t_shift = sim.simulate(shift, C, R).makespan
    t_fa3 = sim.simulate(fa3, C, R).makespan
    lb = sim.ragged_lower_bound(shift, C, R)
    if t_shift > t_fa3 + 1e-9:
        return f"shift ({t_shift}) slower than fa3-order ({t_fa3})"
    if name in STRICT and not t_shift < t_fa3 - 1e-9:
        return (f"shift ({t_shift}) must STRICTLY beat fa3-order ({t_fa3}) "
                "on stacked ragged columns")
    if expect_optimal:
        if abs(t_shift - lb) > 1e-9:
            return f"shift ({t_shift}) misses the lower bound ({lb})"
        d = dag_mod.build_dag(shift, C, R)
        if not d.lemma1_monotone():
            return "collision-free shift placement must be Lemma-1 monotone"
        if abs(d.critical_path(True) - t_shift) > 1e-9:
            return (f"DAG critical path ({d.critical_path(True)}) != "
                    f"simulated makespan ({t_shift})")
    return None, t_shift, t_fa3, lb


def main() -> int:
    failures = []
    for name, n, mask, expect_optimal in sweep():
        res = check(name, n, mask, expect_optimal)
        if isinstance(res, str):
            failures.append((name, n, res))
            print(f"FAIL {name} n={n}: {res}")
        else:
            _, t_shift, t_fa3, lb = res
            opt = "optimal" if abs(t_shift - lb) < 1e-9 else f"lb={lb:.1f}"
            print(f"ok   {name:<15} n={n:>3}: shift={t_shift:7.1f} "
                  f"fa3-order={t_fa3:7.1f} ({t_fa3 / t_shift:4.2f}x, {opt})")
    if failures:
        print(f"{len(failures)} placement check(s) failed", file=sys.stderr)
        return 1
    print("all mask placement checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
