"""CI gate: the autotuner's modeled ranking is stable and lands on the
paper-proven optima.

Golden properties, re-derived per run (no stored goldens to go stale):
  1. two *independent* enumerate+rank passes over the same geometry produce
     the identical ranking (candidate keys in the same order) — the
     determinism half of the tuner contract, checked without any cache;
  2. reversing the candidate list before ranking changes nothing — the
     ranking is a pure function of the candidate *set*, never of enumeration
     order;
  3. for the paper masks the winner family is the paper's analytic optimum:
     ``shift`` on full, ``symmetric_shift`` on causal, worker-parallel
     realization (paper §3.4 — the shift family hits the makespan lower
     bound where a collision-free rotation exists);
  4. for every block-sparse mask of check_mask_placement's sweep,
     ``pick_placement`` chooses ``shift``; on the stacked-column masks
     (document, prefix-LM — the STRICT set over there) shift's modeled
     makespan is STRICTLY below fa3-order's, so the choice is forced, not a
     tie-break;
  5. the cost calibration matches ``bench_schedule_sim.rc_ratio`` — the
     tuner and the paper-figure benchmarks model the same machine.

Run by CI:  PYTHONPATH=src:. python benchmarks/check_tuner_ranking.py
"""
import sys

from repro.masks import Document, PrefixLM, SlidingWindow, streaming_mask
from repro.tune import (enumerate_candidates, modeled_costs, pick_placement,
                        rank_candidates)
from repro.tune.space import Candidate

BLK = 128
STRICT = {"document", "prefix_lm"}


def mask_sweep():
    # same families/sizes as check_mask_placement.py
    for n in (4, 8, 16, 32):
        s = n * BLK
        yield ("sliding_window", n, SlidingWindow(max(BLK, s // 3)))
        yield ("document", n,
               Document.from_lengths((s // 4, s // 2, s - s // 4 - s // 2)))
        yield ("prefix_lm", n, PrefixLM(s // 4))
        yield ("streaming", n, streaming_mask(max(BLK, s // 4), BLK))


def keys_of(ranked):
    return [row["candidate"].key() for row in ranked]


def check_registry(seq, head_dim, causal, want_family):
    """Stability + set-purity + paper-optimal winner for one geometry."""
    kw = dict(seq_q=seq, head_dim=head_dim, causal=causal)
    a = rank_candidates(enumerate_candidates(**kw), **kw)
    b = rank_candidates(enumerate_candidates(**kw), **kw)
    if keys_of(a) != keys_of(b):
        return "two independent rankings disagree"
    rev = rank_candidates(tuple(reversed(enumerate_candidates(**kw))), **kw)
    if keys_of(a) != keys_of(rev):
        return "ranking depends on candidate enumeration order"
    win = a[0]["candidate"]
    if win.schedule != want_family:
        return (f"winner family {win.schedule!r}; the paper optimum is "
                f"{want_family!r}")
    if not win.worker_parallel:
        return "winner must take the worker-parallel realization"
    return None, win, a[0]["modeled_makespan_s"]


def check_mask(name, n, mask):
    """pick_placement chooses shift; strictly better on the STRICT set."""
    placement = pick_placement(mask, n, n, BLK, BLK)
    if placement != "shift":
        return f"pick_placement chose {placement!r}, expected 'shift'"
    costs = {
        p: modeled_costs(Candidate(p, BLK, BLK, True, 0),
                         seq_q=n * BLK, seq_kv=n * BLK, head_dim=128,
                         mask=mask)["modeled_makespan_s"]
        for p in ("shift", "fa3")}
    if costs["shift"] > costs["fa3"] + 1e-15:
        return (f"shift modeled makespan ({costs['shift']:.3e}) above "
                f"fa3-order's ({costs['fa3']:.3e})")
    if name in STRICT and not costs["shift"] < costs["fa3"] - 1e-15:
        return (f"shift must be STRICTLY faster than fa3-order on stacked "
                f"ragged columns; got {costs['shift']:.3e} vs "
                f"{costs['fa3']:.3e}")
    return None, costs


def main() -> int:
    failures = []

    # calibration: one machine model for the tuner and the paper figures
    import benchmarks.bench_schedule_sim as bss
    from repro.tune.model import task_costs
    r_over_c = bss.rc_ratio(128, 128)
    c2, r2 = task_costs(128, 128, 128)
    if abs(r_over_c - r2 / c2) > 1e-9:
        failures.append(("calibration", 0,
                         f"tuner r/c {r2 / c2:.4f} != bench {r_over_c:.4f}"))
        print(f"FAIL calibration: {failures[-1][2]}")
    else:
        print(f"ok   calibration     r/c={r2 / c2:.4f} (matches "
              "bench_schedule_sim)")

    for seq, hd, causal, family in [(1024, 128, False, "shift"),
                                    (1024, 128, True, "symmetric_shift"),
                                    (4096, 64, False, "shift"),
                                    (4096, 64, True, "symmetric_shift")]:
        res = check_registry(seq, hd, causal, family)
        tag = f"{'causal' if causal else 'full'} s={seq} d={hd}"
        if isinstance(res, str):
            failures.append((tag, seq, res))
            print(f"FAIL {tag}: {res}")
        else:
            _, win, mk = res
            print(f"ok   {tag:<22}: {win.key()} "
                  f"(modeled {mk * 1e6:.2f}us)")

    for name, n, mask in mask_sweep():
        res = check_mask(name, n, mask)
        if isinstance(res, str):
            failures.append((name, n, res))
            print(f"FAIL {name} n={n}: {res}")
        else:
            _, costs = res
            print(f"ok   {name:<15} n={n:>3}: shift "
                  f"({costs['fa3'] / costs['shift']:4.2f}x vs fa3-order)")

    if failures:
        print(f"{len(failures)} tuner ranking check(s) failed",
              file=sys.stderr)
        return 1
    print("all tuner ranking checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
